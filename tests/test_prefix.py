"""Prefix-aware KV reuse (DESIGN.md §7): index/trie unit behaviour, the
scheduler's affinity routing and Eq. (2) suffix accounting, multi-turn trace
invariants and parent gating, eviction under memory pressure, invalidation
on flip/retire, the NoSchedulableInstance fix, and sim/engine parity on a
small multiturn trace (hit counts match; engine streams are bit-identical
with the cache on vs off)."""
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (SLO, AutoScalerConfig, GlobalScheduler,
                        InstanceMonitor, InstancePools, InstanceStats,
                        NoSchedulableInstance, Pool, PrefixCacheManager,
                        PrefixHit, PrefixIndex, Request, RequestState,
                        SchedulerConfig, TTFTPredictor, content_keys,
                        lineage_keys)
from repro.core.prefix_index import PrefixEntry
from repro.core.serving import replay_trace
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

CFG = get_config("gemma-2b")
MT_SLO = SLO(TRACE_PRESETS["multiturn"].slo_ttft,
             TRACE_PRESETS["multiturn"].slo_tpot)


# ------------------------------------------------------------- key schemes


def test_lineage_and_content_keys():
    assert lineage_keys(7, 96, block=32) == ((7, 0), (7, 1), (7, 2))
    assert lineage_keys(7, 95, block=32) == ((7, 0), (7, 1))
    assert lineage_keys(7, 31, block=32) == ()
    toks = list(range(100))
    a = content_keys(toks, block=32)
    b = content_keys(toks[:64] + [999] * 36, block=32)
    assert len(a) == 3
    assert a[:2] == b[:2]          # shared 64-token prefix -> shared chain
    assert a[2] != b[2]            # divergence changes every later key
    # regression: the hash must commit to full token ids, not a low byte —
    # ids equal mod 256 are different tokens
    c = content_keys([t + 256 for t in toks], block=32)
    assert a[0] != c[0]


# ------------------------------------------------------------- index/trie


def entry(iid, rid, n_blocks, ns=0, kv=None):
    return PrefixEntry(iid, rid, lineage_keys(ns, n_blocks * 32),
                       kv if kv is not None else n_blocks * 32)


def test_index_longest_prefix_lookup():
    idx = PrefixIndex(block=32)
    idx.insert(entry(0, 10, 4))        # instance 0 caches 4 blocks
    idx.insert(entry(1, 11, 2))        # instance 1 caches 2 blocks
    hits = idx.lookup(lineage_keys(0, 3 * 32))
    # deepest matching node is depth 3: only instance 0 reaches it
    assert hits == [PrefixHit(0, 10, 96)]
    hits = idx.lookup(lineage_keys(0, 2 * 32))
    assert {h.iid for h in hits} == {0, 1}
    assert all(h.cached_len == 64 for h in hits)
    assert idx.lookup(lineage_keys(99, 128)) == []


def test_index_remove_prunes():
    idx = PrefixIndex(block=32)
    idx.insert(entry(0, 1, 3))
    idx.remove(0, 1)
    assert len(idx) == 0
    assert not idx.root.children       # branches pruned
    assert idx.lookup(lineage_keys(0, 96)) == []


def test_manager_lru_eviction_order_and_pins():
    freed = []
    mgr = PrefixCacheManager(block=32,
                             release=lambda i, r, kv: freed.append((i, r)))
    mgr.retain(0, 1, lineage_keys(0, 64), 64)
    mgr.retain(0, 2, lineage_keys(1, 64), 64)
    mgr.retain(0, 3, lineage_keys(2, 64), 64)
    mgr.record_hit(PrefixHit(0, 1, 64))       # rid 1 becomes most-recent
    mgr.pin(0, 2)                             # rid 2 is un-evictable
    assert mgr.make_room(0, 64) == 64
    assert freed == [(0, 3)]                  # LRU unpinned first, not 1 or 2
    assert mgr.make_room(0, 1000) == 64       # only rid 1 remains evictable
    assert (0, 2) not in [f for f in freed]
    assert mgr.stats["evictions"] == 2


def test_invalidate_dooms_pinned_entry_until_unpin():
    freed = []
    mgr = PrefixCacheManager(block=32,
                             release=lambda i, r, kv: freed.append((i, r)))
    mgr.retain(1, 5, lineage_keys(0, 96), 96)
    mgr.pin(1, 5)
    assert mgr.invalidate_instance(1) == 1
    assert mgr.index.lookup(lineage_keys(0, 96)) == []   # gone from lookups
    assert freed == []                                   # but KV still alive
    mgr.unpin(1, 5)
    assert freed == [(1, 5)]                             # freed on last unpin


# --------------------------------------------- scheduler affinity routing


class FakeCluster:
    def has_pending_prefill(self, iid):
        return False

    def has_pending_decode(self, iid):
        return False


def make_sched(n=3, n_prefill=2, slo=SLO(10.0, 0.1), **cfg_kw):
    pools = InstancePools(range(n), n_prefill=n_prefill)
    mon = InstanceMonitor(range(n))
    for i in range(n):
        mon.update_stats(InstanceStats(instance_id=i))
    pred = TTFTPredictor.fit([(0, 0.0), (1000, 0.1), (2000, 0.3), (4000, 1.0)])
    cfg = SchedulerConfig(max_running_tokens=10000, **cfg_kw)
    gs = GlobalScheduler(pools, mon, pred, slo, cfg, FakeCluster())
    return gs, pools, mon


def test_affinity_routes_to_holder_and_charges_suffix():
    gs, pools, mon = make_sched()            # 0,1 PREFILL; 2 DECODE
    req = Request(0, 0.0, 1024, 8)
    hit = PrefixHit(iid=2, rid=50, cached_len=512)
    out = gs.schedule_prefill(req, 0.0, prefix_hits=[hit])
    assert out.instance == 2
    assert out.prefix_hit == PrefixHit(2, 50, 512)
    # Eq. (2): the holder is charged only the uncached suffix
    assert gs.prefill_ready_at[2] == pytest.approx(
        gs.predictor.predict_chunk(512, 512))
    assert gs.prefill_ready_at[0] == 0.0     # cold candidates untouched


def test_affinity_skips_overloaded_decode_holder():
    gs, pools, mon = make_sched()
    cfg = gs.cfg
    mon.update_stats(InstanceStats(
        instance_id=2,
        running_tokens=int(cfg.decode_low_load_frac *
                           cfg.max_running_tokens) + 1))
    out = gs.schedule_prefill(Request(0, 0.0, 1024, 8), 0.0,
                              prefix_hits=[PrefixHit(2, 50, 512)])
    assert out.instance != 2                 # overload guard: decode first
    assert out.prefix_hit is None


def test_affinity_prefers_cold_when_holder_queue_is_long():
    gs, pools, mon = make_sched()
    gs.prefill_ready_at[2] = 100.0           # holder buried in work
    out = gs.schedule_prefill(Request(0, 0.0, 1024, 8), 0.0,
                              prefix_hits=[PrefixHit(2, 50, 512)])
    assert out.instance in (0, 1)
    assert out.prefix_hit is None


# ---------------------------------------------- NoSchedulableInstance fix


def test_schedule_raises_descriptive_error_when_nothing_active():
    gs, pools, mon = make_sched(n=2, n_prefill=1)
    pools.begin_retire(0)
    pools.begin_retire(1)
    with pytest.raises(NoSchedulableInstance, match="prefill.*2 retiring"):
        gs.schedule_prefill(Request(0, 0.0, 64, 4), 0.0)
    with pytest.raises(NoSchedulableInstance, match="decode"):
        gs.schedule_decode(Request(1, 0.0, 64, 4), 0.0)


def test_runtime_queues_unplaced_request_instead_of_crashing():
    """Regression (ISSUE 3): every instance WARMING/RETIRING used to raise a
    bare IndexError from active_ids()[0]; now the request waits and is
    dispatched when capacity appears."""
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow_elastic",
                    slo=SLO(3.0, 0.1),
                    autoscaler_cfg=AutoScalerConfig(min_instances=1,
                                                    max_instances=4))
    sim.begin_retire(0, 0.0)
    sim.begin_retire(1, 0.0)
    h = sim.submit(Request(rid=0, arrival=0.0, input_len=64, output_len=2))
    sim.run_until(1.0)                       # arrival processed: no crash
    assert not h.done
    assert h.req.state is RequestState.QUEUED
    assert h.req.prefill_instance is None
    sim.scale_up(Pool.PREFILL, sim.clock.now())
    report = sim.drain()
    assert report.n_finished == 1 and h.done


# --------------------------------------------------- multiturn trace shape


def test_multiturn_trace_invariants():
    trace = load_trace("multiturn", rate_scale=2.0, seed=0, duration=120)
    assert len(trace) > 50
    by_rid = {r.rid: r for r in trace}
    assert sorted(by_rid) == list(range(len(trace)))
    arr = [r.arrival for r in trace]
    assert arr == sorted(arr)                # rids in arrival order
    followups = [r for r in trace if r.parent_rid is not None]
    assert followups, "preset must generate multi-turn sessions"
    for r in followups:
        p = by_rid[r.parent_rid]
        assert p.session_id == r.session_id
        assert p.rid < r.rid and p.arrival <= r.arrival
        # the child's prompt is the parent's whole context + a fresh message
        assert r.history_len == p.input_len + p.output_len
        assert r.input_len > r.history_len
    # seeded determinism
    again = load_trace("multiturn", rate_scale=2.0, seed=0, duration=120)
    assert [(r.rid, r.arrival, r.input_len, r.parent_rid) for r in trace] == \
           [(r.rid, r.arrival, r.input_len, r.parent_rid) for r in again]


# -------------------------------------------------- sim end-to-end reuse


def mt_sim(prefix_cache, **kw):
    defaults = dict(n_instances=4, n_prefill=2, policy="arrow", slo=MT_SLO)
    defaults.update(kw)
    return Simulator(CFG, prefix_cache=prefix_cache, **defaults)


def test_sim_multiturn_hits_savings_and_parent_gating():
    trace = load_trace("multiturn", rate_scale=2.0, seed=0, duration=120)
    followups = [r for r in trace if r.parent_rid is not None]
    sim = mt_sim(True)
    handles = replay_trace(sim, trace)
    report = sim.drain()
    assert report.n_finished == len(trace)
    by_rid = {h.rid: h for h in handles}
    for h in handles:
        if h.req.parent_rid is None:
            continue
        parent = by_rid[h.req.parent_rid]
        # dispatch gating: a follow-up can never see its first token
        # before the parent finished
        assert h.req.first_token_time >= parent.req.finish_time
    px = report.prefix
    assert px["hits"] >= 0.9 * len(followups)
    assert px["saved_prefill_frac"] >= 0.30        # acceptance threshold
    assert sum(1 for h in handles if h.req.cached_len > 0) == px["hits"]


def test_cache_off_and_sessionless_runs_are_untouched():
    """Acceptance: non-multiturn results are unchanged — cache off is the
    identical code path, and cache *on* over a session-less trace never
    retains or hits (the sim models no content)."""
    p = TRACE_PRESETS["spike"]
    trace = load_trace("spike", rate_scale=2.0, seed=0, duration=60)
    runs = []
    for kw in (dict(), dict(prefix_cache=False), dict(prefix_cache=True)):
        sim = Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow",
                        slo=SLO(p.slo_ttft, p.slo_tpot), **kw)
        replay_trace(sim, trace)
        rep = sim.drain()
        runs.append(([h.ttft for h in rep.handles], rep.decisions))
    assert runs[0] == runs[1] == runs[2]
    # and with the cache on, nothing was ever cached for session-less load
    assert sim.prefix_mgr.stats["retained"] == 0
    assert sim.prefix_mgr.stats["hits"] == 0


def test_multiturn_cache_on_at_least_matches_goodput():
    trace = load_trace("multiturn", rate_scale=4.0, seed=0, duration=120)
    good = {}
    for on in (False, True):
        sim = mt_sim(on, n_instances=2, n_prefill=1)
        replay_trace(sim, trace)
        rep = sim.drain()
        good[on] = (sum(1 for h in rep.handles if h.meets_slo()),
                    rep.percentile("ttft", 0.9))
    assert good[True][0] >= good[False][0]         # goodput no worse
    assert good[True][1] <= good[False][1] + 1e-9  # p90 TTFT no worse


# -------------------------------------------- eviction / invalidation


def test_eviction_under_memory_pressure_frees_lru_first():
    sim = mt_sim(True, n_instances=2, n_prefill=1)
    loc = sim.locals[1]
    for rid, ns in ((100, 0), (101, 1)):
        sim._register(Request(rid, 0.0, 64, 2), "standard", None, None)
        loc.retain_kv(rid, 64)
        sim.prefix_mgr.retain(1, rid, lineage_keys(ns, 64), 64)
    sim.prefix_mgr.record_hit(PrefixHit(1, 100, 64))   # 101 becomes LRU
    loc.kv_capacity = loc.kv_used + 10        # a 50-token import cannot fit
    sim._register(Request(7, 0.0, 50, 3), "standard", None, None)
    sim.handles[7].req.prefill_instance = 0
    loc.enqueue_migration(7, 50, 3)
    sim.admit_migrations(1)
    assert not loc.migration_queue            # admitted after eviction
    assert 101 not in loc.retained and 100 in loc.retained
    assert sim.prefix_mgr.stats["evictions"] == 1


def test_retire_and_flip_invalidate_index():
    sim = mt_sim(True)
    trace = load_trace("multiturn", rate_scale=2.0, seed=1, duration=60)
    replay_trace(sim, trace)
    sim.drain()
    holders = [i for i in sim.pools.all_ids()
               if sim.prefix_mgr.entries_on(i)]
    assert holders, "drained multiturn run must leave retained prefixes"
    victim = holders[0]
    n_before = len(sim.prefix_mgr.entries_on(victim))
    sim.begin_retire(victim, sim.clock.now())
    assert sim.prefix_mgr.entries_on(victim) == []
    assert not sim.locals[victim].retained            # KV actually freed
    assert sim.prefix_mgr.stats["invalidations"] >= n_before
    # pool flip of another holder invalidates too
    others = [i for i in sim.pools.all_ids()
              if sim.prefix_mgr.entries_on(i)]
    if others:
        v2 = others[0]
        if sim.pools.pool_of(v2) in (Pool.DECODE, Pool.P2D):
            sim.pools.flip_to_prefill(v2, False)
        else:
            sim.pools.flip_to_decode(v2, False)
        assert sim.prefix_mgr.entries_on(v2) == []


# --------------------------------------------------- sim/engine parity


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def tiny_multiturn():
    """Two sessions (3 + 2 turns), growing history, engine-capacity sized.
    Every follow-up should hit: 3 expected hits on both backends."""
    return [
        Request(0, 0.00, 40, 3, session_id=0),
        Request(1, 0.05, 36, 2, session_id=1),
        Request(2, 0.10, 81, 3, session_id=0, parent_rid=0, history_len=43),
        Request(3, 0.15, 68, 2, session_id=1, parent_rid=1, history_len=38),
        Request(4, 0.20, 104, 2, session_id=0, parent_rid=2, history_len=84),
    ]


def test_sim_engine_parity_multiturn_hits_and_streams(engine_setup):
    """Acceptance (ISSUE 3): identical cached-prefix hit counts across the
    two backends on the same multiturn trace, and the engine's real greedy
    token streams are bit-identical with the cache on vs off."""
    cfg, params = engine_setup
    trace = tiny_multiturn()
    expected_hits = sum(1 for r in trace if r.parent_rid is not None)

    sim = Simulator(CFG, n_instances=2, n_prefill=1, slo=SLO(5.0, 2.0),
                    prefix_cache=True)
    replay_trace(sim, trace)
    rep_sim = sim.drain()
    assert rep_sim.n_finished == len(trace)
    assert rep_sim.prefix["hits"] == expected_hits

    from repro.engine import ArrowEngineCluster
    streams = {}
    eng_hits = None
    for on in (False, True):
        eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(5.0, 2.0),
                                 params=params, prefix_cache=on)
        toks = {}
        replay_trace(eng, trace,
                     on_token=lambda h, tok, t:
                     toks.setdefault(h.rid, []).append(tok))
        rep = eng.drain(timeout=300.0)
        assert rep.n_finished == len(trace)
        streams[on] = toks
        if on:
            eng_hits = rep.prefix["hits"]
    assert eng_hits == rep_sim.prefix["hits"] == expected_hits
    for r in trace:
        assert len(streams[True][r.rid]) == r.output_len
        assert all(isinstance(t, int) for t in streams[True][r.rid])
    # copy-on-extend is exact: greedy streams don't change with reuse
    assert streams[True] == streams[False]


def test_engine_slot_eviction_under_pressure(engine_setup):
    """Retained slots are reclaimable capacity: with every slot retained, a
    new prefill evicts the LRU prefix instead of deadlocking."""
    cfg, params = engine_setup
    from repro.engine import ArrowEngineCluster
    eng = ArrowEngineCluster(cfg, n_instances=1, n_prefill=1, n_slots=2,
                             capacity=128, slo=SLO(10.0, 5.0), params=params,
                             prefix_cache=True)
    # two single-turn sessions fill both slots with retained prefixes
    replay_trace(eng, [Request(0, 0.0, 40, 2, session_id=0),
                       Request(1, 0.0, 40, 2, session_id=1)])
    eng.drain(timeout=300.0)
    inst = eng.instances[0]
    assert len(inst.local.retained) == 2 and not inst.kv.free
    # a third, unrelated request needs a slot -> one retained prefix evicted
    h = eng.submit(Request(2, 0.0, 40, 2))
    rep = eng.drain(timeout=300.0)
    assert h.done and rep.n_finished == 3
    assert eng.prefix_mgr.stats["evictions"] >= 1
