"""StateSlots protocol tests (DESIGN.md §13): per-architecture decode state
on the engine hot path.

Three claims, checked per implementation (dense SlotKVCache, SSMStateSlots,
RecurrentStateSlots):

  * migration bit-identity — a stream continued on another instance after a
    real ``export_state``/``import_state`` round-trip produces exactly the
    tokens an unmigrated instance produces;
  * O(1) vs O(L) wire size — the exported payload's nbytes is constant in
    context length for recurrent state and linear for attention KV;
  * capability flags — the factory hands back the flags the scheduler keys
    on (prefix reuse mode, active-mask need, speculation support).

Plus Pallas-vs-reference parity for the ssm/hybrid engine hot path itself
(``ssd_scan``/``rglru_scan`` in interpret mode drive the jitted fused step).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.engine import EngineInstance
from repro.engine.kv_slots import SlotKVCache
from repro.engine.state_slots import (RecurrentStateSlots, SSMStateSlots,
                                      make_state_slots)
from repro.models import build_model

ARCHS = ["qwen3-1.7b", "mamba2-370m", "recurrentgemma-9b"]


@pytest.fixture(scope="module", params=ARCHS)
def setup(request):
    cfg = get_smoke_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def decode_stream(inst, rid, prompt, n_new):
    """Prefill + n_new greedy decode steps on one instance."""
    toks = [inst.run_prefill(rid, prompt)]
    inst.local.start_local_decode(rid, len(prompt), n_new)
    for _ in range(n_new):
        toks.append(inst.run_decode_iteration([rid])[rid])
    return toks


# ------------------------------------------------- migration bit-identity


def test_state_transfer_preserves_generation(setup):
    """The stateless-instance property, per StateSlots impl: decode continued
    on another instance after export_state/import_state is bit-identical to
    an unmigrated decode."""
    cfg, model, params = setup
    ref = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    a = EngineInstance(1, cfg, params, n_slots=4, capacity=128)
    b = EngineInstance(2, cfg, params, n_slots=4, capacity=128)
    prompt = np.arange(1, 25, dtype=np.int32)
    want = decode_stream(ref, 7, prompt, 7)

    got = [a.run_prefill(7, prompt)]
    a.local.start_local_decode(7, len(prompt), 3)
    for _ in range(3):
        got.append(a.run_decode_iteration([7])[7])
    payload, L, last, gen = a.export_state(7)
    assert L == len(prompt) + 3
    assert b.import_state(7, payload, L, last, gen)
    a.drop(7)
    b.local.start_local_decode(7, L, 4)
    for _ in range(4):
        got.append(b.run_decode_iteration([7])[7])
    assert got == want, f"{cfg.family}: migrated stream diverged"


# ----------------------------------------------- payload size: O(1) vs O(L)


def test_payload_bytes_scaling(setup):
    """Recurrent state is a fixed-size summary — exported nbytes must not
    depend on context length. Attention KV must grow with it (§13)."""
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)

    def wire_bytes(rid, prompt_len, n_dec):
        prompt = np.arange(1, prompt_len + 1, dtype=np.int32)
        decode_stream(inst, rid, prompt, n_dec)
        payload, L, _, _ = inst.export_state(rid)
        assert L == prompt_len + n_dec
        inst.drop(rid)
        return sum(int(np.asarray(p).nbytes) for p in payload)

    short = wire_bytes(1, 8, 2)
    long = wire_bytes(2, 80, 2)
    if cfg.family == "dense":
        # KV is bucket-padded to 32-token slabs: 10 tokens vs 82 tokens
        assert long > short
    else:
        assert long == short, \
            f"{cfg.family} state must be O(1) in context, got {short}->{long}"
    # the host-side accounting the cost model reads agrees in shape
    prompt = np.arange(1, 41, dtype=np.int32)
    decode_stream(inst, 3, prompt, 1)
    assert inst.kv.state_bytes(3) > 0
    inst.drop(3)


# ------------------------------------------ engine hot path: pallas parity


def test_engine_pallas_matches_reference(setup):
    """The fused jitted step with Pallas kernels (ssd_scan / rglru_scan /
    paged_attention in interpret mode on CPU) produces the same greedy
    stream as the pure-jnp reference path, under real engine decode shapes
    (slot slabs, bucketed prefill)."""
    cfg, model, params = setup
    r = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    p = EngineInstance(1, cfg.replace(attn_impl="pallas"), params,
                       n_slots=4, capacity=128)
    prompt = np.arange(3, 40, dtype=np.int32)
    assert decode_stream(r, 5, prompt, 6) == decode_stream(p, 5, prompt, 6)


# ----------------------------------------------------------- capabilities


def test_factory_capability_flags():
    """make_state_slots picks the impl + flags the scheduler keys on."""
    for arch, klass, reuse, mask, spec in [
            ("qwen3-1.7b", SlotKVCache, "block", False, True),
            ("mamba2-370m", SSMStateSlots, "exact", True, False),
            ("recurrentgemma-9b", RecurrentStateSlots, "exact", True, False)]:
        cfg = get_smoke_config(arch)
        slots = make_state_slots(cfg, n_slots=2, capacity=64)
        assert type(slots) is klass
        assert slots.prefix_reuse == reuse
        assert slots.needs_active_mask is mask
        assert slots.supports_speculation is spec
