"""Multi-tenant credit scheduling + admission control (ISSUE 6, DESIGN.md
§10): the credit ledger / registry / retry-queue units, the watermark
decision zones, WDRR dispatch fairness, tenant_id end-to-end on the sim,
report-surface guards (percentile nearest-rank, n/a rendering), sim/engine
admission parity, the invariant probe with a flooder active, and the
satellite regressions (rejections never strand ``drain()``, cascaded
parent rejection)."""
import pytest
from invariants import check_invariants

from repro.configs import get_config
from repro.core import Request, SLO
from repro.core.request import RequestState
from repro.core.serving import RequestHandle, ServeReport, TIERS, replay_trace
from repro.core.tenants import (AdmissionConfig, Admitted, CreditLedger,
                                CreditLedgerConfig, Deferred, Rejected,
                                RetryQueue, Tenant, TenantRegistry,
                                default_registry)
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

CFG = get_config("gemma-2b")


# --------------------------------------------------------------- units


def test_tenant_validation():
    assert Tenant("a").tier == "standard" and Tenant("a").weight == 1.0
    with pytest.raises(ValueError, match="unknown SLO tier"):
        Tenant("a", tier="platinum")
    with pytest.raises(ValueError, match="weight must be > 0"):
        Tenant("a", weight=0.0)


def test_ledger_accrual_earns_debits_and_clamps():
    cfg = CreditLedgerConfig(earn_rate=2.0, debit_rate=4.0, initial=8.0,
                             cap=20.0)
    led = CreditLedger(cfg)
    t = Tenant("t", weight=2.0)
    led.open(t)
    assert led.balance("t") == 16.0                 # initial × weight
    led.open(t)
    assert led.balance("t") == 16.0                 # idempotent
    # zero violations: earn at weight-scaled rate, clamp at cap × weight
    assert led.accrue(t, 0.0, dt=1.0) == pytest.approx(20.0)
    assert led.accrue(t, 0.0, dt=100.0) == pytest.approx(40.0)   # cap 2×20
    # full violations: debit, floor at zero
    assert led.accrue(t, 1.0, dt=1.0) == pytest.approx(32.0)     # -4×2
    assert led.accrue(t, 1.0, dt=1000.0) == 0.0
    # mixed: (earn×(1-v) - debit×v) × weight × dt
    led._balance["t"] = 10.0
    assert led.accrue(t, 0.25, dt=1.0) == pytest.approx(
        10.0 + 2.0 * (2.0 * 0.75 - 4.0 * 0.25))


def test_ledger_spend_is_gated_drain_is_not():
    led = CreditLedger(CreditLedgerConfig(initial=5.0))
    led.open(Tenant("t"))
    assert led.spend("t", 4.0) and led.balance("t") == 1.0
    assert not led.spend("t", 2.0) and led.balance("t") == 1.0
    led.drain("t", 100.0)                           # ungated, zero floor
    assert led.balance("t") == 0.0
    assert led.balance("ghost") == 0.0 and not led.spend("ghost", 0.1)


def test_retry_queue_bounds_and_attempts():
    q = RetryQueue(maxlen=2)
    assert q.offer(1, deadline=5.0) and q.offer(2, deadline=6.0)
    assert not q.offer(3, deadline=7.0)             # full
    assert q.offer(1, deadline=9.9)                 # re-offer bumps attempts
    assert q.attempts[1] == 2 and q.deadline(1) == 5.0   # deadline is fixed
    assert len(q) == 2 and 1 in q and 3 not in q
    q.remove(1)
    assert len(q) == 1 and 1 not in q and q.deadline(1) is None
    q.remove(1)                                     # idempotent


def test_registry_counters_violation_ewma_and_ticks():
    reg = TenantRegistry([Tenant("a", tier="interactive", weight=2.0)])
    with pytest.raises(ValueError, match="already registered"):
        reg.register(Tenant("a"))
    assert reg.ensure("b").tier == "standard"       # auto-registered
    assert set(reg.ids()) == {"a", "b"} and len(reg) == 2 and "a" in reg
    reg.note_submit("a"); reg.note_admit("a"); reg.note_defer("a")
    reg.note_reject("a", shed=False); reg.note_reject("a", shed=True)
    reg.note_finish("a", met_slo=True)
    c = reg.counters["a"]
    assert (c["submitted"], c["admitted"], c["deferred"]) == (1, 1, 1)
    assert (c["rejected"], c["shed"], c["finished"], c["slo_ok"]) \
        == (1, 1, 1, 1)
    # EWMA saw miss, miss, hit with alpha 0.2
    v = 0.0
    for x in (1.0, 1.0, 0.0):
        v += 0.2 * (x - v)
    assert reg.violation_ewma("a") == pytest.approx(v)
    # first tick only records the baseline; the second accrues dt
    reg.on_tick(10.0)
    bal = reg.credits("a")
    reg.on_tick(11.0)
    assert reg.credits("a") > bal
    reg.on_tick(10.5)                               # non-monotonic: no-op
    assert reg.credits("a") == reg.credits("a")


def test_default_registry_roster():
    reg = default_registry(4)
    assert reg.ids() == ["t0", "t1", "t2", "t3", "flood"]
    assert [reg.get(f"t{i}").tier for i in range(4)] == \
        ["interactive", "standard", "batch", "interactive"]
    assert reg.get("t0").weight == 2.0 and reg.get("t2").weight == 0.5
    assert "flood" not in default_registry(2, flooder=False)


# ------------------------------------------------- watermark decision zones


def make_ctl(monkeypatch, pressure, *, initial=10.0, **cfg_kw):
    """A live controller on a tiny sim with the pressure signal pinned."""
    reg = TenantRegistry(
        [Tenant("t")],
        ledger=CreditLedger(CreditLedgerConfig(initial=initial,
                                               earn_rate=0.0)))
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(10.0, 1.0), tenants=reg,
                    admission=AdmissionConfig(cost_per_token=1.0, **cfg_kw))
    ctl = sim.admission_ctl
    monkeypatch.setattr(ctl, "pressure", lambda now: pressure)
    return sim, ctl


def handle_for(rid, *, arrival=0.0, tenant="t"):
    req = Request(rid=rid, arrival=arrival, input_len=3, output_len=1,
                  tenant_id=tenant)                 # cost = 4.0 credits
    return RequestHandle(req=req, slo=SLO(10.0, 1.0))


def test_low_zone_admits_everyone_and_drains(monkeypatch):
    sim, ctl = make_ctl(monkeypatch, 0.1, initial=1.0)   # can't afford 4.0
    d = ctl.consider(handle_for(1), now=0.0)
    assert isinstance(d, Admitted) and d.cost == 4.0
    assert sim.tenants.credits("t") == 0.0          # drained to the floor
    assert ctl.stats["admitted"] == 1


def test_credit_zone_spends_then_defers_then_rejects(monkeypatch):
    sim, ctl = make_ctl(monkeypatch, 0.8, initial=4.0)
    assert isinstance(ctl.consider(handle_for(1), now=0.0), Admitted)
    assert sim.tenants.credits("t") == 0.0
    # out of credit before the deadline (arrival + 1.0 × slo.ttft): defer
    d = ctl.consider(handle_for(2), now=0.0)
    assert isinstance(d, Deferred)
    assert d.retry_at == pytest.approx(0.25) and d.deadline == 10.0
    assert 2 in ctl.retry_queue and ctl.stats["deferred"] == 1
    # re-delivery while still broke: another Deferred, counted as a retry
    d2 = ctl.consider(handle_for(2), now=0.25)
    assert isinstance(d2, Deferred) and ctl.stats["retries"] == 1
    assert ctl.stats["deferred"] == 1               # not double-counted
    # past the deadline: typed rejection, queue entry cleaned up
    d3 = ctl.consider(handle_for(2), now=10.0)
    assert isinstance(d3, Rejected) and d3.reason == "no_credit"
    assert 2 not in ctl.retry_queue and ctl.is_rejected(2)
    assert sim.tenants.counters["t"]["rejected"] == 1


def test_credit_zone_bounded_queue_rejects_overflow(monkeypatch):
    sim, ctl = make_ctl(monkeypatch, 0.8, initial=0.0, retry_queue_len=1)
    assert isinstance(ctl.consider(handle_for(1), now=0.0), Deferred)
    d = ctl.consider(handle_for(2), now=0.0)
    assert isinstance(d, Rejected) and d.reason == "retry_queue_full"
    assert d.retry_after > 0


def test_shed_zone_charges_premium_never_queues(monkeypatch):
    # premium = 4.0 cost × 4.0 premium = 16.0: affordable exactly once
    sim, ctl = make_ctl(monkeypatch, 5.0, initial=16.0)
    d = ctl.consider(handle_for(1), now=0.0)
    assert isinstance(d, Admitted) and sim.tenants.credits("t") == 0.0
    d2 = ctl.consider(handle_for(2), now=0.0)
    assert isinstance(d2, Rejected) and d2.reason == "overload"
    assert len(ctl.retry_queue) == 0                # shed never defers
    assert ctl.stats["shed"] == 1 and ctl.stats["rejected"] == 0
    assert sim.tenants.counters["t"]["shed"] == 1
    assert sim.tenants.violation_ewma("t") > 0      # shed is a violation


def test_admission_is_sticky_never_recharges(monkeypatch):
    sim, ctl = make_ctl(monkeypatch, 0.8, initial=4.0)
    h = handle_for(1)
    assert isinstance(ctl.consider(h, now=0.0), Admitted)
    # crash-recovery / unplaced re-dispatch re-delivers the same rid
    d = ctl.consider(h, now=1.0)
    assert isinstance(d, Admitted) and d.cost == 0.0
    assert sim.tenants.credits("t") == 0.0          # charged exactly once
    assert ctl.stats["admitted"] == 1


# ----------------------------------------------------------- WDRR dispatch


def test_single_tenant_plan_is_plain_fifo():
    from repro.core import LocalScheduler
    a = LocalScheduler(0, token_budget=256, mixed_chunk_budget=64)
    b = LocalScheduler(1, token_budget=256, mixed_chunk_budget=64)
    for i in range(5):
        a.enqueue_prefill(i, 100)                       # unlabelled
        b.enqueue_prefill(i, 100, tenant="t", weight=2.0)  # one tenant
    b._drr_deficit["ghost"] = 99.0                  # must be cleared
    assert a.plan_iteration().prefill_chunks == \
        b.plan_iteration().prefill_chunks
    assert b._drr_deficit == {}


def test_wdrr_starved_head_beats_flooder_backlog():
    from repro.core import LocalScheduler
    loc = LocalScheduler(0, token_budget=256, mixed_chunk_budget=64)
    for i in range(8):                              # flooder got there first
        loc.enqueue_prefill(i, 64, tenant="flood", weight=1.0)
    loc.enqueue_prefill(100, 64, tenant="vip", weight=2.0)
    chunks = loc.plan_iteration().prefill_chunks
    rids = [rid for rid, _, _ in chunks]
    assert 100 in rids[:2], f"vip head-of-line starved: {rids}"
    # the flooder is served its share, not the whole budget
    assert 0 < sum(1 for r in rids if r < 100) < 8


def test_wdrr_share_ratio_tracks_weights():
    from repro.core import LocalScheduler
    loc = LocalScheduler(0, token_budget=512, mixed_chunk_budget=64)
    for i in range(16):
        loc.enqueue_prefill(i, 64, tenant="small", weight=0.5)
        loc.enqueue_prefill(100 + i, 64, tenant="big", weight=1.0)
    served = {"small": 0, "big": 0}
    # two plans (half the backlog): both queues stay saturated, so the
    # served split reflects the weights, not residual demand
    for _ in range(2):
        for rid, done, chunk in loc.plan_iteration().prefill_chunks:
            served["small" if rid < 100 else "big"] += chunk
            loc.complete_prefill_chunk(rid, chunk)
    assert served["big"] > served["small"] > 0
    assert served["big"] / served["small"] == pytest.approx(2.0, rel=0.5)


# ------------------------------------------- report-surface guards (sat 2/3)


def report_with_ttfts(vals):
    hs = []
    for i, v in enumerate(vals):
        req = Request(rid=i, arrival=0.0, input_len=4, output_len=2)
        req.first_token_time = v
        hs.append(RequestHandle(req=req, slo=SLO(5.0, 2.0)))
    return ServeReport(handles=hs)


def test_percentile_is_ceil_nearest_rank():
    rep = report_with_ttfts(range(1, 11))           # ttfts 1..10
    assert rep.percentile("ttft", 0.50) == 5        # ceil(5.0)  -> 5th
    assert rep.percentile("ttft", 0.90) == 9        # ceil(9.0)  -> 9th
    assert rep.percentile("ttft", 0.99) == 10       # ceil(9.9)  -> 10th
    assert rep.percentile("ttft", 1.00) == 10
    # the old floor-index form was biased low on small n: p99 of 2 samples
    # must be the max, not the min
    rep2 = report_with_ttfts([1.0, 2.0])
    assert rep2.percentile("ttft", 0.99) == 2.0
    assert rep2.percentile("ttft", 0.01) == 1.0     # rank floor is 1
    assert report_with_ttfts([]).percentile("ttft", 0.9) is None


def test_attainment_by_tier_empty_guards():
    rep = ServeReport(handles=[])
    assert rep.attainment_by_tier() == {}
    forced = rep.attainment_by_tier(tiers=["interactive", "batch"])
    assert forced == {"interactive": None, "batch": None}
    assert rep.attainment == 1.0                    # vacuous, no crash
    assert "n/a" in rep.summary() and "tenants=" not in rep.summary()


def test_tenant_summary_renders_na_for_empty_tenants():
    rep = ServeReport(handles=[], per_tenant={
        "idle": {"tier": "batch", "attainment": None, "p99_ttft": None,
                 "p99_tpot": None, "admitted": 0, "submitted": 0,
                 "rejected": 0, "shed": 0, "credits": 4.0},
        "busy": {"tier": "standard", "attainment": 0.5, "p99_ttft": 0.25,
                 "p99_tpot": 0.01, "admitted": 2, "submitted": 3,
                 "rejected": 1, "shed": 0, "credits": 0.0},
    })
    text = rep.tenant_summary()
    busy, idle = text.splitlines()[:2]              # sorted: busy first
    assert "att=0.50" in busy and "p99_ttft=250.0ms" in busy
    assert "att=n/a" in idle and "p99_ttft=n/a" in idle
    assert "adm=0/0" in idle and "credits=4.0" in idle
    assert "tenants=2" in rep.summary()


# ----------------------------------------------------- sim end-to-end (§10)


def test_tenant_id_flows_end_to_end_and_tier_overrides():
    reg = TenantRegistry([Tenant("vip", tier="interactive", weight=2.0)])
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 1.0), tenants=reg)
    h = sim.submit(Request(rid=1, arrival=0.0, input_len=32, output_len=4),
                   tier="batch", tenant_id="vip")
    h2 = sim.submit(Request(rid=2, arrival=0.0, input_len=32, output_len=4))
    rep = sim.drain()
    assert h.tenant_id == "vip" and h.req.tenant_id == "vip"
    assert h.tier == "interactive"                  # registry overrides
    assert h.slo.ttft == TIERS["interactive"].apply(SLO(5.0, 1.0)).ttft
    # untagged requests in a tenanted run fall into the anonymous bucket
    # (call-site tier kept) so finish accounting matches admission charges
    assert h2.tenant_id == "anonymous" and h2.tier == "standard"
    assert rep.per_tenant["vip"]["finished"] == 1
    assert rep.per_tenant["vip"]["tier"] == "interactive"
    assert rep.per_tenant["anonymous"]["finished"] == 1
    assert rep.admission == {}                      # admission was off


def test_sim_flooder_run_with_probe_every_step():
    """Acceptance: the invariant probe passes on every step with the
    flooder active and admission rejecting (REJECTED rids hold nothing)."""
    p = TRACE_PRESETS["tenants"]
    trace = load_trace("tenants", rate_scale=8.0, seed=0, duration=30.0)
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(p.slo_ttft, p.slo_tpot),
                    tenants=default_registry(4),
                    admission=AdmissionConfig(low_watermark=0.05,
                                              high_watermark=0.5,
                                              deadline_scale=0.5))
    replay_trace(sim, trace)
    while sim.step():
        check_invariants(sim, streams=False)
    check_invariants(sim)
    rep = sim.report()
    rejected = [h for h in rep.handles if h.rejected]
    assert rejected, "flooder run never exercised rejection"
    assert rep.admission["rejected"] + rep.admission["shed"] == len(rejected)
    assert rep.admission["admitted"] == rep.n_finished
    assert rep.n_finished + len(rejected) == len(trace)
    for h in rejected:
        assert h.req.state is RequestState.REJECTED
        assert h.rejection.reason in ("overload", "no_credit",
                                      "retry_queue_full")
        assert not h.done and h.ttft is None
    # per-tenant counters reconcile with the handle view
    for tid, row in rep.per_tenant.items():
        mine = [h for h in rep.handles if h.tenant_id == tid]
        assert row["submitted"] == len(mine)
        assert row["rejected"] + row["shed"] == \
            sum(1 for h in mine if h.rejected)
    assert "admitted=" in rep.summary() and "tenants=5" in rep.summary()


def test_deferred_requests_recover_when_credits_accrue():
    """A briefly-broke tenant is deferred, then admitted on retry once the
    monitor tick accrues credits — not rejected."""
    reg = TenantRegistry([Tenant("t")], ledger=CreditLedger(
        CreditLedgerConfig(initial=0.0, earn_rate=50.0, cap=1000.0)))
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(30.0, 1.0), tenants=reg,
                    admission=AdmissionConfig(low_watermark=0.0,
                                              high_watermark=1e9,
                                              cost_per_token=1.0))
    h = sim.submit(Request(rid=1, arrival=0.0, input_len=16, output_len=2,
                           tenant_id="t"))
    rep = sim.drain()
    assert h.done and not h.rejected
    assert rep.admission["deferred"] == 1 and rep.admission["admitted"] == 1
    assert h.ttft > 0.5          # it actually waited for accrual


# -------------------------------------------------- rejection regressions


def broke_admission():
    """Registry + config under which every request is rejected at once."""
    reg = TenantRegistry([Tenant("t")], ledger=CreditLedger(
        CreditLedgerConfig(initial=0.0, earn_rate=0.0)))
    cfg = AdmissionConfig(low_watermark=0.0, cost_per_token=1.0,
                          deadline_scale=0.0)    # deadline == arrival
    return reg, cfg


def test_rejected_rids_never_strand_drain():
    """Satellite: every instance RETIRING and only typed rejections
    outstanding — drain() completes instead of raising
    UndispatchableError (rejected rids never reach the stranded scan)."""
    reg, cfg = broke_admission()
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(3.0, 0.1), tenants=reg, admission=cfg)
    sim.begin_retire(0, 0.0)
    sim.begin_retire(1, 0.0)
    h = sim.submit(Request(rid=7, arrival=0.0, input_len=32, output_len=2,
                           tenant_id="t"))
    rep = sim.drain()                              # no UndispatchableError
    assert h.rejected and h.rejection.reason in ("overload", "no_credit")
    assert rep.n_finished == 0 and rep.admission["admitted"] == 0
    from repro.core.tenants import rejected_state_consistent
    assert rejected_state_consistent(h)
    check_invariants(sim)


def test_rejection_cascades_to_gated_children():
    reg, cfg = broke_admission()
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(3.0, 0.1), tenants=reg, admission=cfg)
    # child arrives BEFORE the parent: gated first, released by rejection
    early = sim.submit(Request(rid=2, arrival=0.0, input_len=16,
                               output_len=2, tenant_id="t", session_id=9,
                               parent_rid=1, history_len=8))
    parent = sim.submit(Request(rid=1, arrival=0.5, input_len=16,
                                output_len=2, tenant_id="t", session_id=9))
    # child arriving AFTER the parent was already rejected
    late = sim.submit(Request(rid=3, arrival=1.0, input_len=16,
                              output_len=2, tenant_id="t", session_id=9,
                              parent_rid=1, history_len=8))
    sim.drain()
    assert parent.rejected and parent.rejection.reason == "no_credit"
    for child in (early, late):
        assert child.rejected
        assert child.rejection.reason == "parent_rejected"
    check_invariants(sim)


def test_invariant_probe_fires_on_corrupted_rejected_state():
    """The REJECTED invariant is falsifiable: smuggling scheduling state
    onto a rejected handle must trip the probe."""
    reg, cfg = broke_admission()
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(3.0, 0.1), tenants=reg, admission=cfg)
    h = sim.submit(Request(rid=1, arrival=0.0, input_len=16, output_len=2,
                           tenant_id="t"))
    sim.drain()
    assert h.rejected
    h.req.prefill_instance = 0                     # corrupt on purpose
    with pytest.raises(AssertionError, match="rejected rid 1"):
        check_invariants(sim)


# ------------------------------------------------------ sim/engine parity


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def parity_requests():
    # deterministic costs: 36 credits each under cost_per_token=1.0
    return [Request(rid=i, arrival=0.0, input_len=32, output_len=4,
                    tenant_id="p") for i in range(6)]


def parity_admission():
    # earn/debit both zero: balances are pure spend arithmetic, identical
    # under the sim's virtual ticks and the engine's wall-clock ticks
    reg = TenantRegistry([Tenant("p")], ledger=CreditLedger(
        CreditLedgerConfig(initial=80.0, earn_rate=0.0, debit_rate=0.0)))
    cfg = AdmissionConfig(low_watermark=-1.0, high_watermark=1e9,
                          cost_per_token=1.0, deadline_scale=0.0)
    return reg, cfg


def decisions(handles):
    return [(h.rid, h.rejection.reason if h.rejected else "admitted")
            for h in handles]


def test_sim_engine_admission_parity(engine_setup):
    """Acceptance: the same seeded trace yields identical per-rid
    admit/reject decisions on both backends at the drain barrier."""
    from repro.engine import ArrowEngineCluster
    ecfg, params = engine_setup

    reg, acfg = parity_admission()
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), tenants=reg, admission=acfg)
    sim_h = [sim.submit(r) for r in parity_requests()]
    sim.drain()

    reg2, acfg2 = parity_admission()
    eng = ArrowEngineCluster(ecfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             tenants=reg2, admission=acfg2)
    eng_h = [eng.submit(r) for r in parity_requests()]
    eng.drain(timeout=300.0)

    want = [(0, "admitted"), (1, "admitted"), (2, "no_credit"),
            (3, "no_credit"), (4, "no_credit"), (5, "no_credit")]
    assert decisions(sim_h) == want
    assert decisions(eng_h) == want
    # both charged exactly twice: 80 - 2×36
    assert reg.credits("p") == reg2.credits("p") == pytest.approx(8.0)
    for h in eng_h[:2]:
        assert h.done and len(h.tokens) == 4       # admitted ones really ran
    check_invariants(eng)
