"""Simulator + trace tests: conservation, metric sanity, paper-direction
claims at fixed load points, and Insight-5 load-timing structure."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.sim.simulator import SimResult
from repro.traces import TRACE_PRESETS, load_trace, trace_stats

CFG = get_config("gemma-2b")


def drain_result(sim) -> SimResult:
    """Drain through the ServingSystem API and snapshot the legacy
    SimResult view (per-request records + attainment/flips) the assertions
    below read — the deprecated ``Simulator.run`` shim returned the same."""
    sim.drain()
    return SimResult(list(sim.requests.values()), sim.slo,
                     flips=sim.pools.flips, sim_time=sim.clock.now())


def run(policy, rate, trace_name="azure_code", duration=90, **kw):
    trace = load_trace(trace_name, rate_scale=rate, seed=0, duration=duration)
    p = TRACE_PRESETS[trace_name]
    sim = Simulator(CFG, n_instances=8, n_prefill=4, policy=policy,
                    slo=SLO(p.slo_ttft, p.slo_tpot), **kw)
    replay_trace(sim, trace)
    return drain_result(sim), trace


@pytest.mark.parametrize("policy", ["arrow", "minimal_load", "round_robin",
                                    "colocated"])
def test_all_requests_complete(policy):
    res, trace = run(policy, rate=4.0)
    assert len(res.requests) == len(trace)
    for r in res.requests:
        assert r.finish_time is not None, r.rid
        assert r.first_token_time is not None
        assert r.finish_time >= r.first_token_time >= r.arrival
        # exactly output_len tokens: o_1 at prefill + (m-1) decode iterations
        assert r.decoded_tokens == max(r.output_len - 1, 0)


def test_ttft_tpot_definitions():
    res, _ = run("arrow", rate=2.0)
    for r in res.requests:
        assert r.ttft >= 0
        if r.output_len == 1:
            assert r.tpot == 0.0          # Eq. (3) m=1 case
        else:
            assert r.tpot >= 0


def test_traces_match_published_structure():
    """Fig. 1/2 targets: azure_code bursty + strongly correlated, mooncake
    stable + long-input, burstgpt the burstiest."""
    s_code = trace_stats(load_trace("azure_code", seed=0))
    s_moon = trace_stats(load_trace("mooncake", seed=0))
    s_burst = trace_stats(load_trace("burstgpt", seed=0))
    s_conv = trace_stats(load_trace("azure_conv", seed=0))
    assert s_code["in_out_corr"] > 0.85                 # paper: r = 0.95
    assert s_conv["in_out_corr"] < 0.5                  # paper: r = 0.29
    assert s_code["input_cv_per_min"] > 2 * s_moon["input_cv_per_min"]
    assert s_burst["input_cv_per_min"] > 0.5
    assert s_moon["input_median"] > 4 * s_code["input_median"]
    assert s_code["input_median"] > 10 * s_code["output_median"]


def test_arrow_beats_static_disagg_under_load():
    """Paper Fig. 7 direction: at overload for the static PD split, Arrow
    sustains a much higher attainment."""
    res_arrow, _ = run("arrow", rate=24.0)
    res_static, _ = run("minimal_load", rate=24.0)
    assert res_arrow.attainment > res_static.attainment + 0.2
    assert res_arrow.flips > 0


def test_arrow_close_to_or_above_static_at_low_load():
    res_arrow, _ = run("arrow", rate=2.0)
    res_static, _ = run("minimal_load", rate=2.0)
    assert res_arrow.attainment >= res_static.attainment - 0.02


def test_minimal_load_beats_round_robin():
    """Fig. 8: min-load request scheduling >= round robin (small margin)."""
    a, _ = run("minimal_load", rate=16.0)
    b, _ = run("round_robin", rate=16.0)
    assert a.attainment >= b.attainment - 0.01


def test_prefill_load_leads_decode_load():
    """Insight 5 (Fig. 4): under a burst, the mandatory prefill→decode order
    makes prefill load peak strictly before decode load."""
    from repro.core.request import Request
    from repro.core.slo import SchedulerConfig
    burst = [Request(rid=i, arrival=0.01 * i, input_len=16384, output_len=400)
             for i in range(50)]
    sim = Simulator(CFG, n_instances=8, n_prefill=4, policy="minimal_load",
                    slo=SLO(2.0, 0.15),
                    sched_cfg=SchedulerConfig(monitor_interval=0.05))
    prefill_hist, decode_hist = [], []
    orig = sim.policy.on_monitor_tick

    def tick(now):
        orig(now)
        p = sum(len(sim.locals[i].prefill_queue) for i in range(8))
        d = sum(len(sim.locals[i].decode_running) for i in range(8))
        prefill_hist.append((now, p))
        decode_hist.append((now, d))

    sim.policy.on_monitor_tick = tick
    replay_trace(sim, burst)
    sim.drain()
    tp = max(prefill_hist, key=lambda x: x[1])[0]
    td = max(decode_hist, key=lambda x: x[1])[0]
    assert tp < td    # prefill peak strictly earlier


def test_flip_latency_degrades_attainment():
    """§3.2 motivation: the same adaptive policy with a 30s per-flip reload
    penalty (legacy systems) does no better than zero-cost stateless flips."""
    res_free, _ = run("arrow", rate=16.0)
    trace = load_trace("azure_code", rate_scale=16.0, seed=0, duration=90)
    sim = Simulator(CFG, n_instances=8, n_prefill=4, policy="arrow",
                    slo=SLO(3.0, 0.1), flip_latency=30.0)
    replay_trace(sim, trace)
    res_slow = drain_result(sim)
    assert res_free.attainment >= res_slow.attainment


def test_proactive_policy_runs_and_flips():
    res, _ = run("arrow_proactive", rate=16.0)
    assert res.attainment > 0.5
    assert all(r.finish_time is not None for r in res.requests)


def test_heterogeneous_cluster_prefers_fast_instances():
    """Paper §8: per-instance profiles + per-instance TTFT predictors. Under
    Arrow, the fast instances should absorb more prefill work."""
    from repro.sim import InstanceProfile
    profiles = {i: InstanceProfile(chips=8 if i < 2 else 2) for i in range(8)}
    trace = load_trace("azure_code", rate_scale=8.0, seed=0, duration=60)
    sim = Simulator(CFG, n_instances=8, n_prefill=4, policy="arrow",
                    slo=SLO(3.0, 0.1), profiles=profiles)
    replay_trace(sim, trace)
    res = drain_result(sim)
    assert all(r.finish_time is not None for r in res.requests)
    counts = {i: 0 for i in range(8)}
    for r in res.requests:
        counts[r.prefill_instance] += r.input_len
    fast = counts[0] + counts[1]
    slow = counts[6] + counts[7]
    assert fast > slow, counts
    # predictor really is per-instance
    p0 = sim.predictor.for_instance(0).predict(8192)
    p7 = sim.predictor.for_instance(7).predict(8192)
    assert p0 < p7


def test_scalability_more_instances_help():
    """Fig. 9 direction: attainment grows with instance count."""
    trace = load_trace("azure_code", rate_scale=16.0, seed=0, duration=90)
    outs = []
    for n in (4, 8, 16):
        sim = Simulator(CFG, n_instances=n, n_prefill=n // 2, policy="arrow",
                        slo=SLO(3.0, 0.1))
        replay_trace(sim, trace)
        outs.append(drain_result(sim).attainment)
    assert outs[0] <= outs[1] + 0.02 and outs[1] <= outs[2] + 0.02
    assert outs[2] > outs[0]
